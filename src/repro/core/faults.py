"""``FaultProcess`` — registry-driven client-side fault injection.

Mirrors the channel-scenario subsystem (``repro.core.channels.process``):
a fault family is a frozen, hashable dataclass whose scalar knobs are
*traced* hyper-parameters (the ``TracedHyperParams`` mixin), registered
under a family name, and applied as a pure jittable function.  Faults are
injected by ``repro.fl.AsyncFLTrainer._round_impl`` between ``local_sgd``
and the Eq.-6 buffer carry — exactly the point where a real deployment's
client-side failures corrupt the upload path:

  dropout    client unavailable this round (straggler/crash): it neither
             finishes local training nor transmits — the classic
             dropout/straggler mask.
  nan_grads  non-finite gradient corruption: the client's flattened (P,)
             update row is replaced with NaN (or Inf for a fraction of
             hits) — fp overflow / bad batch / poisoned loss.
  byte_flip  update scaling by 2**exponent on hit rows — a flipped
             exponent bit in transit; finite but norm-exploded, the case
             the quarantine's ``max_update_norm`` cap exists for.
  sign_flip  Byzantine sign-flip: hit rows upload ``-scale * G`` — finite
             and norm-modest, so it sails through the quarantine gate;
             the defense is robust aggregation
             (``repro.core.aggregation``).
  inner_product
             ALIE-style colluding inner-product attack: hit rows all
             upload ``-strength * mean(honest rows)``, the perturbation
             aimed exactly along the honest-mean direction, computed from
             the (M, P) batch inside ``_inject``.  Also quarantine-clean
             by construction.
  burst      not a corruption itself but a *schedule*: wraps any base
             family and modulates its ``rate`` knob with a Gilbert-
             Elliott-style Markov on/off carry (burst faults rather than
             i.i.d. Bernoulli).  The carry threads through the trainer
             scans as ``fault_state`` — see ``inject_sched``.

``inject(key, t, updates)`` returns ``(updates', dropped)`` where
``dropped`` is the (M,) f32 {0, 1} unavailability mask.  All randomness
comes from ``key`` (derive it per round: the trainer folds a fault tag
into the round key, so the no-fault PRNG stream is untouched); all knobs
are read from the ``sp`` pytree inside ``_inject``, never from ``self``,
so fault grids vmap through one program exactly like scenario grids —
stack instances with ``repro.core.bandits.base.stack_params`` and vmap
``inject`` over the stacked ``params`` axis, or vmap over keys for
per-seed draws.

Graceful degradation lives downstream: the round runtime's quarantine
(Step 4 of ``repro.fl.round``) masks non-finite / norm-exploded buffer
rows out of aggregation, revokes their ``has_update`` and re-issues the
global model so the client retries with a fresh update — see
``src/repro/sim/README.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core.bandits.base import TracedHyperParams
from repro.core.channels.process import check_knobs


@dataclasses.dataclass(frozen=True)
class FaultProcess(TracedHyperParams):
    """Base class: a hashable fault-family description.

    Subclasses set ``FAMILY``/``TRACED`` and implement ``_inject``:

      _inject(key, t, updates, sp)  the generator: (M, P) fresh client
                                    updates in, (updates', dropped) out;
                                    every traced knob read from ``sp``.
      example()                     a default instance — lets tests and
                                    benchmarks enumerate the registry.

    Families with *temporal structure* (fault schedules) additionally
    override the carried-state hooks:

      schedule_init()               the family's carried schedule state —
                                    a dead f32 scalar zero for memoryless
                                    families (keeps the trainer state
                                    pytree structure fixed).
      _inject_sched(key, t, updates, fstate, sp)
                                    stateful generator returning
                                    (updates', dropped, fstate').  The
                                    default delegates to ``_inject`` with
                                    the SAME key and passes ``fstate``
                                    through — memoryless families stay
                                    bitwise-identical to their pre-
                                    schedule behavior.
    """

    FAMILY: ClassVar[str] = ""

    def _inject(self, key: jax.Array, t: jnp.ndarray,
                updates: jnp.ndarray, sp) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    @classmethod
    def example(cls) -> "FaultProcess":
        return cls()

    def schedule_init(self) -> jnp.ndarray:
        """Initial carried schedule state (dead zero scalar by default)."""
        return jnp.zeros((), jnp.float32)

    def _inject_sched(self, key, t, updates, fstate, sp):
        out, dropped = self._inject(key, t, updates, sp)
        return out, dropped, fstate

    def inject(self, key: jax.Array, t: jnp.ndarray, updates: jnp.ndarray,
               params=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Apply the fault family to a round's fresh (M, P) updates.

        ``params`` optionally overrides the traced knobs (``self.params()``
        pytree) — the grid-vmap hook, same convention as
        ``ChannelProcess.realize``.  Returns ``(updates', dropped)`` with
        ``dropped`` an (M,) f32 {0, 1} client-unavailability mask.
        Stateless view: schedule-carrying families run from their initial
        schedule state (the trainers thread the carry via
        ``inject_sched``).
        """
        if params is None or not jax.tree_util.tree_leaves(params):
            params = self.params()
        out, dropped, _ = self._inject_sched(
            key, t, updates, self.schedule_init(), params)
        return out, dropped

    def inject_sched(self, key: jax.Array, t: jnp.ndarray,
                     updates: jnp.ndarray, fstate, params=None):
        """Stateful injection: ``(updates', dropped, fstate')``.

        The trainer-scan entry point: ``fstate`` is the carried schedule
        state (``schedule_init()`` at round 0), advanced once per round.
        Memoryless families consume the key identically to ``inject`` and
        return ``fstate`` untouched, so threading the carry changes no
        existing PRNG stream.
        """
        if params is None or not jax.tree_util.tree_leaves(params):
            params = self.params()
        return self._inject_sched(key, t, updates, fstate, params)


# ---------------------------------------------------------------------------
# registry (mirrors repro.core.channels.process)
# ---------------------------------------------------------------------------

_FAULT_REGISTRY: Dict[str, Type[FaultProcess]] = {}


def register_fault(cls: Type[FaultProcess]) -> Type[FaultProcess]:
    """Class decorator: add a fault family to the registry."""
    if not cls.FAMILY:
        raise ValueError(f"register_fault: {cls.__name__} has no FAMILY name")
    if cls.FAMILY in _FAULT_REGISTRY:
        raise ValueError(f"register_fault: duplicate family {cls.FAMILY!r}")
    _FAULT_REGISTRY[cls.FAMILY] = cls
    return cls


def registered_faults() -> Dict[str, Type[FaultProcess]]:
    """Name -> class for every registered fault family (a copy)."""
    return dict(_FAULT_REGISTRY)


def make_fault(family: str, **kwargs) -> FaultProcess:
    """Construct a fault process by registry name.  Unknown or missing
    knobs raise eagerly with the family's valid knob list (same eager
    check as ``make_scenario``)."""
    try:
        cls = _FAULT_REGISTRY[family]
    except KeyError:
        raise ValueError(
            f"make_fault: unknown family {family!r}; registered: "
            f"{sorted(_FAULT_REGISTRY)}") from None
    check_knobs(cls, f"make_fault({family!r})", kwargs)
    return cls(**kwargs)


def example_fault(family: str) -> FaultProcess:
    """The family's default example instance."""
    try:
        cls = _FAULT_REGISTRY[family]
    except KeyError:
        raise ValueError(
            f"example_fault: unknown family {family!r}; registered: "
            f"{sorted(_FAULT_REGISTRY)}") from None
    return cls.example()


# ---------------------------------------------------------------------------
# built-in families
# ---------------------------------------------------------------------------

@register_fault
@dataclasses.dataclass(frozen=True)
class DropoutFaults(FaultProcess):
    """Per-round Bernoulli client unavailability (straggler/crash).

    A dropped client neither finishes local training (its buffered G~ is
    kept, Eq. 6) nor transmits this round — the runtime zeroes both its
    Eq.-6 refresh and its transmission success.
    """

    rate: float = 0.1

    FAMILY = "dropout"
    TRACED = ("rate",)

    def _inject(self, key, t, updates, sp):
        m = updates.shape[0]
        dropped = jax.random.bernoulli(
            key, jnp.clip(sp["rate"], 0.0, 1.0), (m,)).astype(jnp.float32)
        return updates, dropped


@register_fault
@dataclasses.dataclass(frozen=True)
class NaNGradFaults(FaultProcess):
    """Non-finite gradient corruption: hit rows become all-NaN (or all-Inf
    for fraction ``inf_frac`` of hits) — fp overflow, bad batches, or a
    poisoned loss on the client."""

    rate: float = 0.1
    inf_frac: float = 0.0

    FAMILY = "nan_grads"
    TRACED = ("rate", "inf_frac")

    def _inject(self, key, t, updates, sp):
        m = updates.shape[0]
        k0, k1 = jax.random.split(key)
        hit = jax.random.bernoulli(k0, jnp.clip(sp["rate"], 0.0, 1.0), (m,))
        use_inf = jax.random.bernoulli(
            k1, jnp.clip(sp["inf_frac"], 0.0, 1.0), (m,))
        bad = jnp.where(use_inf, jnp.inf, jnp.nan)
        corrupted = jnp.where(hit[:, None], bad[:, None], updates)
        return corrupted, jnp.zeros((m,), jnp.float32)


@register_fault
@dataclasses.dataclass(frozen=True)
class ByteFlipFaults(FaultProcess):
    """Exponent-bit flip in transit: hit rows are scaled by
    ``2**exponent`` — finite but norm-exploded.  Caught by the
    quarantine's ``max_update_norm`` cap (a plain finiteness check would
    let it through and destroy the global model in one round)."""

    rate: float = 0.05
    exponent: float = 24.0

    FAMILY = "byte_flip"
    TRACED = ("rate", "exponent")

    def _inject(self, key, t, updates, sp):
        m = updates.shape[0]
        hit = jax.random.bernoulli(key, jnp.clip(sp["rate"], 0.0, 1.0), (m,))
        factor = jnp.where(hit, jnp.exp2(sp["exponent"]), 1.0)
        return updates * factor[:, None], jnp.zeros((m,), jnp.float32)


@register_fault
@dataclasses.dataclass(frozen=True)
class SignFlipFaults(FaultProcess):
    """Byzantine sign-flip: hit rows upload ``-scale * G``.

    Finite and (for modest ``scale``) norm-ordinary, so the quarantine's
    finiteness and norm gates pass it — with the default ``mean``
    aggregator the expected step direction becomes
    ``(1 - rate*(1 + scale)) * G``, i.e. gradient *ascent* once
    ``rate * (1 + scale) > 1``.  Contained by the robust aggregators
    (``repro.core.aggregation``): flipped rows are coordinate-wise
    extremes on the wrong side and get trimmed/out-voted."""

    rate: float = 0.2
    scale: float = 3.0

    FAMILY = "sign_flip"
    TRACED = ("rate", "scale")

    def _inject(self, key, t, updates, sp):
        m = updates.shape[0]
        hit = jax.random.bernoulli(key, jnp.clip(sp["rate"], 0.0, 1.0), (m,))
        factor = jnp.where(hit, -sp["scale"], 1.0)
        return updates * factor[:, None], jnp.zeros((m,), jnp.float32)


@register_fault
@dataclasses.dataclass(frozen=True)
class InnerProductFaults(FaultProcess):
    """ALIE-style colluding inner-product attack.

    Every hit (Byzantine) row uploads the SAME vector
    ``-strength * mean(honest rows)`` — a perturbation aimed exactly
    along the honest-mean direction, computed from the round's (M, P)
    batch inside ``_inject`` (the colluders see each other's honest
    peers, the strongest standard threat model).  Norm-comparable to an
    honest update, so quarantine is blind to it; with ``mean`` the
    aggregate direction flips once ``rate * (1 + strength) > 1``, while
    coordinate-wise robust aggregators treat the colluding copies as a
    minority block and trim them."""

    rate: float = 0.2
    strength: float = 3.0

    FAMILY = "inner_product"
    TRACED = ("rate", "strength")

    def _inject(self, key, t, updates, sp):
        m = updates.shape[0]
        hit = jax.random.bernoulli(key, jnp.clip(sp["rate"], 0.0, 1.0), (m,))
        honest = (~hit).astype(jnp.float32)
        n_honest = jnp.maximum(jnp.sum(honest), 1.0)
        mean_honest = jnp.sum(
            updates.astype(jnp.float32) * honest[:, None], axis=0) / n_honest
        attack = -sp["strength"] * mean_honest
        out = jnp.where(hit[:, None], attack[None, :].astype(updates.dtype),
                        updates)
        return out, jnp.zeros((m,), jnp.float32)


@register_fault
@dataclasses.dataclass(frozen=True)
class BurstFaults(FaultProcess):
    """Gilbert-Elliott-style burst schedule over any base fault family.

    Not a corruption itself: a two-state Markov on/off carry (entry rate
    ``p_on``, exit rate ``p_off``) modulates the base family's ``rate``
    knob — ``rate * on_scale`` while bursting, ``rate * off_scale``
    otherwise (defaults: full rate in bursts, silent between).  The
    stationary burst occupancy is ``p_on / (p_on + p_off)``; the carry
    rides the trainer scans as ``fault_state`` (``inject_sched``), so a
    burst grid vmaps through one program like any other fault grid.  The
    stateless ``inject`` view runs from the calm (off) state.
    """

    base: FaultProcess = dataclasses.field(
        default_factory=lambda: SignFlipFaults())
    p_on: float = 0.1
    p_off: float = 0.25
    on_scale: float = 1.0
    off_scale: float = 0.0

    FAMILY = "burst"
    TRACED = ("p_on", "p_off", "on_scale", "off_scale")

    def __post_init__(self):
        if "rate" not in self.base.traced_fields():
            raise ValueError(
                f"BurstFaults: base family {type(self.base).__name__!r} has "
                "no traced 'rate' knob to modulate")

    def params(self):
        """Schedule knobs plus the base family's params nested under
        "base" (the ``JammingOverlay`` idiom)."""
        sp = super().params()
        sp["base"] = self.base.params()
        return sp

    def _inject_sched(self, key, t, updates, fstate, sp):
        k_flip, k_base = jax.random.split(key)
        on = fstate > 0.5
        mod = jnp.where(on, sp["on_scale"], sp["off_scale"])
        bp = dict(sp["base"])
        bp["rate"] = jnp.clip(bp["rate"] * mod, 0.0, 1.0)
        out, dropped = self.base._inject(k_base, t, updates, bp)
        p_flip = jnp.where(on, jnp.clip(sp["p_off"], 0.0, 1.0),
                           jnp.clip(sp["p_on"], 0.0, 1.0))
        flip = jax.random.bernoulli(k_flip, p_flip)
        nxt = jnp.where(flip, 1.0 - fstate, fstate)
        return out, dropped, nxt

    def _inject(self, key, t, updates, sp):
        out, dropped, _ = self._inject_sched(
            key, t, updates, self.schedule_init(), sp)
        return out, dropped
