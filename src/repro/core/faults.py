"""``FaultProcess`` — registry-driven client-side fault injection.

Mirrors the channel-scenario subsystem (``repro.core.channels.process``):
a fault family is a frozen, hashable dataclass whose scalar knobs are
*traced* hyper-parameters (the ``TracedHyperParams`` mixin), registered
under a family name, and applied as a pure jittable function.  Faults are
injected by ``repro.fl.AsyncFLTrainer._round_impl`` between ``local_sgd``
and the Eq.-6 buffer carry — exactly the point where a real deployment's
client-side failures corrupt the upload path:

  dropout    client unavailable this round (straggler/crash): it neither
             finishes local training nor transmits — the classic
             dropout/straggler mask.
  nan_grads  non-finite gradient corruption: the client's flattened (P,)
             update row is replaced with NaN (or Inf for a fraction of
             hits) — fp overflow / bad batch / poisoned loss.
  byte_flip  update scaling by 2**exponent on hit rows — a flipped
             exponent bit in transit; finite but norm-exploded, the case
             the quarantine's ``max_update_norm`` cap exists for.

``inject(key, t, updates)`` returns ``(updates', dropped)`` where
``dropped`` is the (M,) f32 {0, 1} unavailability mask.  All randomness
comes from ``key`` (derive it per round: the trainer folds a fault tag
into the round key, so the no-fault PRNG stream is untouched); all knobs
are read from the ``sp`` pytree inside ``_inject``, never from ``self``,
so fault grids vmap through one program exactly like scenario grids —
stack instances with ``repro.core.bandits.base.stack_params`` and vmap
``inject`` over the stacked ``params`` axis, or vmap over keys for
per-seed draws.

Graceful degradation lives downstream: the round runtime's quarantine
(Step 4 of ``repro.fl.round``) masks non-finite / norm-exploded buffer
rows out of aggregation, revokes their ``has_update`` and re-issues the
global model so the client retries with a fresh update — see
``src/repro/sim/README.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core.bandits.base import TracedHyperParams
from repro.core.channels.process import check_knobs


@dataclasses.dataclass(frozen=True)
class FaultProcess(TracedHyperParams):
    """Base class: a hashable fault-family description.

    Subclasses set ``FAMILY``/``TRACED`` and implement ``_inject``:

      _inject(key, t, updates, sp)  the generator: (M, P) fresh client
                                    updates in, (updates', dropped) out;
                                    every traced knob read from ``sp``.
      example()                     a default instance — lets tests and
                                    benchmarks enumerate the registry.
    """

    FAMILY: ClassVar[str] = ""

    def _inject(self, key: jax.Array, t: jnp.ndarray,
                updates: jnp.ndarray, sp) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    @classmethod
    def example(cls) -> "FaultProcess":
        return cls()

    def inject(self, key: jax.Array, t: jnp.ndarray, updates: jnp.ndarray,
               params=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Apply the fault family to a round's fresh (M, P) updates.

        ``params`` optionally overrides the traced knobs (``self.params()``
        pytree) — the grid-vmap hook, same convention as
        ``ChannelProcess.realize``.  Returns ``(updates', dropped)`` with
        ``dropped`` an (M,) f32 {0, 1} client-unavailability mask.
        """
        if params is None or not jax.tree_util.tree_leaves(params):
            params = self.params()
        return self._inject(key, t, updates, params)


# ---------------------------------------------------------------------------
# registry (mirrors repro.core.channels.process)
# ---------------------------------------------------------------------------

_FAULT_REGISTRY: Dict[str, Type[FaultProcess]] = {}


def register_fault(cls: Type[FaultProcess]) -> Type[FaultProcess]:
    """Class decorator: add a fault family to the registry."""
    if not cls.FAMILY:
        raise ValueError(f"register_fault: {cls.__name__} has no FAMILY name")
    if cls.FAMILY in _FAULT_REGISTRY:
        raise ValueError(f"register_fault: duplicate family {cls.FAMILY!r}")
    _FAULT_REGISTRY[cls.FAMILY] = cls
    return cls


def registered_faults() -> Dict[str, Type[FaultProcess]]:
    """Name -> class for every registered fault family (a copy)."""
    return dict(_FAULT_REGISTRY)


def make_fault(family: str, **kwargs) -> FaultProcess:
    """Construct a fault process by registry name.  Unknown or missing
    knobs raise eagerly with the family's valid knob list (same eager
    check as ``make_scenario``)."""
    try:
        cls = _FAULT_REGISTRY[family]
    except KeyError:
        raise ValueError(
            f"make_fault: unknown family {family!r}; registered: "
            f"{sorted(_FAULT_REGISTRY)}") from None
    check_knobs(cls, f"make_fault({family!r})", kwargs)
    return cls(**kwargs)


def example_fault(family: str) -> FaultProcess:
    """The family's default example instance."""
    try:
        cls = _FAULT_REGISTRY[family]
    except KeyError:
        raise ValueError(
            f"example_fault: unknown family {family!r}; registered: "
            f"{sorted(_FAULT_REGISTRY)}") from None
    return cls.example()


# ---------------------------------------------------------------------------
# built-in families
# ---------------------------------------------------------------------------

@register_fault
@dataclasses.dataclass(frozen=True)
class DropoutFaults(FaultProcess):
    """Per-round Bernoulli client unavailability (straggler/crash).

    A dropped client neither finishes local training (its buffered G~ is
    kept, Eq. 6) nor transmits this round — the runtime zeroes both its
    Eq.-6 refresh and its transmission success.
    """

    rate: float = 0.1

    FAMILY = "dropout"
    TRACED = ("rate",)

    def _inject(self, key, t, updates, sp):
        m = updates.shape[0]
        dropped = jax.random.bernoulli(
            key, jnp.clip(sp["rate"], 0.0, 1.0), (m,)).astype(jnp.float32)
        return updates, dropped


@register_fault
@dataclasses.dataclass(frozen=True)
class NaNGradFaults(FaultProcess):
    """Non-finite gradient corruption: hit rows become all-NaN (or all-Inf
    for fraction ``inf_frac`` of hits) — fp overflow, bad batches, or a
    poisoned loss on the client."""

    rate: float = 0.1
    inf_frac: float = 0.0

    FAMILY = "nan_grads"
    TRACED = ("rate", "inf_frac")

    def _inject(self, key, t, updates, sp):
        m = updates.shape[0]
        k0, k1 = jax.random.split(key)
        hit = jax.random.bernoulli(k0, jnp.clip(sp["rate"], 0.0, 1.0), (m,))
        use_inf = jax.random.bernoulli(
            k1, jnp.clip(sp["inf_frac"], 0.0, 1.0), (m,))
        bad = jnp.where(use_inf, jnp.inf, jnp.nan)
        corrupted = jnp.where(hit[:, None], bad[:, None], updates)
        return corrupted, jnp.zeros((m,), jnp.float32)


@register_fault
@dataclasses.dataclass(frozen=True)
class ByteFlipFaults(FaultProcess):
    """Exponent-bit flip in transit: hit rows are scaled by
    ``2**exponent`` — finite but norm-exploded.  Caught by the
    quarantine's ``max_update_norm`` cap (a plain finiteness check would
    let it through and destroy the global model in one round)."""

    rate: float = 0.05
    exponent: float = 24.0

    FAMILY = "byte_flip"
    TRACED = ("rate", "exponent")

    def _inject(self, key, t, updates, sp):
        m = updates.shape[0]
        hit = jax.random.bernoulli(key, jnp.clip(sp["rate"], 0.0, 1.0), (m,))
        factor = jnp.where(hit, jnp.exp2(sp["exponent"]), 1.0)
        return updates * factor[:, None], jnp.zeros((m,), jnp.float32)
