"""Marginal-contribution estimation (Sec. V, Eq. 32-35 and 41-43).

The Shapley value (Eq. 32) is approximated with the FedCE-style estimator
the paper adopts:

    C~_m = Gamma_cos * Gamma_err
    Gamma_cos = 1 - cos( grad F_m(w_t^m), grad F(w_t^{-m}) )      (Eq. 34)
    Gamma_err = E( D^_m ; w_t^{-m} )                              (Eq. 35)

where ``w^{-m}`` / ``grad F(w^{-m})`` are leave-one-out (LOO) aggregates.
Under non-stationary channels fresh client updates are not always
available, so the server keeps a *buffer* of the most recent gradient and
parameter vector per client (Eq. 41-42) and computes the LOO quantities
from it.  Aggregation weights are the normalized contributions (Eq. 43).

All functions operate on flattened gradient matrices ``(M, P)`` so the
same code serves the CIFAR-scale FL examples and the sharded LLM runtime
(where P is the per-shard parameter count and the cosine reduces over the
mesh via the surrounding pjit).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

_EPS = 1e-12


class ContributionBuffer(NamedTuple):
    """Server-side buffer (Eq. 41-42): last-known per-client grad + params."""

    grads: jnp.ndarray      # (M, P) buffered gradient vectors  nabla F~(w^m)
    params: jnp.ndarray     # (M, P) buffered parameter vectors w~_m
    fresh: jnp.ndarray      # (M,)   1.0 once a client has ever reported


def init_buffer(n_clients: int, n_params: int) -> ContributionBuffer:
    return ContributionBuffer(
        grads=jnp.zeros((n_clients, n_params), jnp.float32),
        params=jnp.zeros((n_clients, n_params), jnp.float32),
        fresh=jnp.zeros((n_clients,), jnp.float32),
    )


def update_buffer(
    buf: ContributionBuffer,
    success: jnp.ndarray,       # (M,) bool — clients whose upload arrived
    new_grads: jnp.ndarray,     # (M, P) this round's (possibly stale) updates
    new_params: jnp.ndarray,    # (M, P) the local params they were taken at
) -> ContributionBuffer:
    s = success.astype(jnp.float32)[:, None]
    return ContributionBuffer(
        grads=buf.grads * (1.0 - s) + new_grads * s,
        params=buf.params * (1.0 - s) + new_params * s,
        fresh=jnp.maximum(buf.fresh, success.astype(jnp.float32)),
    )


def _cosine(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    num = jnp.sum(a * b, axis=-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1)
    return num / jnp.maximum(den, _EPS)


def loo_aggregates(buf: ContributionBuffer, weights: jnp.ndarray):
    """Leave-one-out weighted aggregates for every client at once.

    Eq. 41-42 with zeta-weights: for each m,
        g^{-m} = (sum_i zeta_i g_i - zeta_m g_m) / (1 - zeta_m)
    Returns (grads^{-m} (M, P), params^{-m} (M, P)).
    """
    w = (weights * buf.fresh)[:, None]                    # ignore never-seen clients
    wsum = jnp.maximum(jnp.sum(w), _EPS)
    g_tot = jnp.sum(w * buf.grads, axis=0, keepdims=True)
    p_tot = jnp.sum(w * buf.params, axis=0, keepdims=True)
    denom = jnp.maximum(wsum - w, _EPS)
    g_loo = (g_tot - w * buf.grads) / denom
    p_loo = (p_tot - w * buf.params) / denom
    return g_loo, p_loo


def marginal_contribution(
    buf: ContributionBuffer,
    weights: jnp.ndarray,
    proxy_loss_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
) -> jnp.ndarray:
    """C~_m = Gamma_cos(m) * Gamma_err(m)  (Eq. 33).

    proxy_loss_fn: maps a flattened parameter vector to the server's proxy
    loss (Eq. 35).  When None (e.g. at LLM dry-run scale, where a proxy
    eval per client per round is not deployable), Gamma_err = 1 and the
    estimator degrades gracefully to the cosine term.
    """
    g_loo, p_loo = loo_aggregates(buf, weights)
    gamma_cos = 1.0 - _cosine(buf.grads, g_loo)           # Eq. 34: in [0, 2]
    if proxy_loss_fn is not None:
        gamma_err = jax.vmap(proxy_loss_fn)(p_loo)        # Eq. 35
    else:
        gamma_err = jnp.ones_like(gamma_cos)
    contrib = gamma_cos * gamma_err
    # never-seen clients get the mean contribution (uninformative prior)
    seen = buf.fresh > 0.5
    fill = jnp.sum(jnp.where(seen, contrib, 0.0)) / jnp.maximum(jnp.sum(seen), 1.0)
    fill = jnp.where(jnp.any(seen), fill, 1.0)
    return jnp.where(seen, contrib, fill)


def aggregation_weights(contrib: jnp.ndarray) -> jnp.ndarray:
    """Eq. 43: zeta_m = C~_m / sum_l C~_l (clipped to be a valid simplex point)."""
    c = jnp.maximum(contrib, _EPS)
    return c / jnp.sum(c)


def exact_shapley(
    utility_fn: Callable[[jnp.ndarray], jnp.ndarray], n_clients: int
) -> jnp.ndarray:
    """Exact Shapley values (Eq. 32) by subset enumeration — O(2^M).

    ``utility_fn`` maps a (M,) 0/1 membership mask to the coalition's
    utility U(S).  Tractable for the paper's experiment scales (M <= ~16);
    used to validate the FedCE-style estimator (Eq. 33) against ground
    truth in tests/benchmarks, not in the runtime path.
    """
    import itertools
    import math

    m = n_clients
    values = jnp.zeros((m,))
    # cache utilities per subset bitmask
    utils = {}

    def u(mask_bits):
        if mask_bits not in utils:
            mask = jnp.array([(mask_bits >> i) & 1 for i in range(m)], jnp.float32)
            utils[mask_bits] = utility_fn(mask)
        return utils[mask_bits]

    fact = math.factorial
    for i in range(m):
        acc = 0.0
        others = [j for j in range(m) if j != i]
        for r in range(m):
            w = fact(r) * fact(m - r - 1) / fact(m)
            for subset in itertools.combinations(others, r):
                bits = sum(1 << j for j in subset)
                acc += w * float(u(bits | (1 << i)) - u(bits))
        values = values.at[i].set(acc)
    return values
