"""Flat-pytree checkpointing: npz payload + json manifest.

Handles the framework's flat ``{path: array}`` parameter dicts as well as
arbitrary nested pytrees (optimizer / FL / bandit state) by flattening with
'/'-joined key paths.  Writes are atomic (tmp + rename) so an interrupted
save never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)   # lossless widening; npz-portable
        flat[key or "_root"] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    path = os.path.join(directory, f"step_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(directory, f"step_{step}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def restore_checkpoint(directory: str, step: Optional[int] = None, like: Any = None):
    """Restore; if ``like`` is given, unflatten into its structure/dtypes."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    with np.load(os.path.join(directory, f"step_{step}.npz")) as data:
        flat = {k: data[k] for k in data.files}
    if like is None:
        return flat, step
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path) or "_root"
        arr = flat[key]
        # jnp.array (not asarray): the device buffer must OWN its bytes.  A
        # zero-copy alias of the np.load array is unsafe to donate — the
        # numpy side frees the memory while XLA may still write into it
        # (restored serve/train state feeds donated executables).
        out.append(jnp.array(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out
    ), step


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := _STEP_RE.search(f))
    ]
    return max(steps) if steps else None
